"""Logical-axis sharding rules per (architecture x shape x mesh).

MaxText-style: params carry logical axis names (built by the ParamBuilder);
``make_rules`` maps every logical name to a mesh axis (or None = replicate)
based on divisibility and the shape kind.  The ShardingCtx applies activation
constraints inside the model; param/optimizer shardings are derived from the
axes tree.

Key decisions (rationale in DESIGN.md §6):
* batch        -> ("pod","data") when divisible (else ("data",), else None).
* heads/mlp/vocab/inner -> "model" when divisible; attention activations for
  small-head archs (qwen 40H, llama4 40H, gemma 8H) replicate over model
  (weight-only TP) — recorded honestly in the roofline.
* experts      -> expert parallelism over the data axes (all-to-all dispatch).
* embed_fsdp   -> data axes for TRAIN (ZeRO-3-style weight sharding; optimizer
  state follows params), replicated for inference shapes (weights fit via
  TP+EP at serve time).
* seq_act      -> "model" for train (Megatron-SP sequence-sharded residual
  stream: bounds the remat stash for the big-d archs), None for inference.
* kv cache time axis -> "data" only for long_500k (batch=1: flash-decoding
  style sequence sharding); batch axis otherwise.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.layers import ShardingCtx


def _div(a: int, b: int) -> bool:
    return b > 0 and a > 0 and a % b == 0


def make_rules(cfg: ModelConfig, mesh, shape: ShapeSpec) -> Dict[str, object]:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)
    data_axes = tuple(a for a in ("pod", "data") if a in sizes)
    n_data = int(np.prod([sizes[a] for a in data_axes])) if data_axes else 1
    is_train = shape.kind == "train"
    is_decode = shape.kind == "decode"

    # batch placement
    gb = shape.global_batch
    if _div(gb, n_data):
        batch = data_axes if len(data_axes) > 1 else data_axes[0]
    elif _div(gb, sizes.get("data", 1)):
        batch = "data"
    else:
        batch = None

    # expert parallelism axes.  Expert-rich archs (deepseek) use padded
    # pure-EP weights (one expert per chip over data x model — hillclimb A);
    # small-E archs shard experts over the data axes with TP'd expert FFNs.
    from repro.models.moe import expert_alloc

    E = cfg.n_experts
    if E and expert_alloc(E) != E:
        experts = ("data", "model")
    elif _div(E, n_data):
        experts = data_axes if len(data_axes) > 1 else data_axes[0]
    elif _div(E, sizes.get("data", 1)):
        experts = "data"
    elif _div(E, model):
        experts = "model"
    else:
        experts = None

    def model_if(n):
        return "model" if _div(n, model) else None

    # Megatron-SP sequence sharding of the residual stream bounds the remat
    # stash (saved block inputs).  It costs an AG+RS per block, so enable it
    # only when the per-device stash would otherwise crowd out HBM.
    stash_bytes = (gb / max(1, n_data)) * shape.seq_len * cfg.d_model * 2 \
        * cfg.n_layers
    seq_act = (model_if(shape.seq_len)
               if (is_train and stash_bytes > 8e9) else None)
    # pure-EP dispatch needs model-axis-unique tokens: sequence-shard the
    # residual stream at prefill too for expert-rich archs (hillclimb A)
    if cfg.n_experts >= 64 and shape.kind == "prefill":
        seq_act = model_if(shape.seq_len)

    rules: Dict[str, object] = {
        # ---- activations ----
        "batch": batch,
        "seq": None,
        "seq_act": seq_act,
        "heads_act": model_if(cfg.n_heads),
        # sequence-parallel attention fallback for head-unshardable archs
        "attn_seq_q": (None if _div(cfg.n_heads, model)
                       else model_if(shape.seq_len)),
        "kv_heads_act": model_if(cfg.n_kv_heads),
        "mlp_act": "model",
        "expert_mlp_act": model_if(cfg.d_ff_expert),
        "inner_act": model_if(cfg.d_inner),
        # ---- weights ----
        "embed_fsdp": ((data_axes if len(data_axes) > 1 else data_axes[0])
                       if (is_train and data_axes) else None),
        "vocab": model_if(cfg.padded_vocab),
        "heads": model_if(cfg.n_heads),
        "kv_heads": model_if(cfg.n_kv_heads),
        # weight-storage fallback (hillclimb B iter 2): when heads don't
        # divide the model axis, shard attention weights on head_dim instead
        # (XLA re-shards activations to the seq-parallel layout cheaply)
        "head_dim": (None if _div(cfg.n_heads, model)
                     else model_if(cfg.head_dim)),
        "qk_dim": None,
        "mlp": "model",
        "experts": experts,
        # padded pure-EP keeps each expert's FFN whole on its chip
        "expert_mlp": (None if experts == ("data", "model")
                       else model_if(cfg.d_ff_expert)),
        "qlora": None,
        "kvlora": None,
        "inner": model_if(cfg.d_inner),
        "ssm_heads": model_if(cfg.ssm_heads),
        "ssm_dim": None,
        "state_nosplit": None,
        "heads_x_dim": model_if(cfg.d_model if cfg.family == "ssm" else 0),
        "mix": None,
        "lora": None,
        "conv": None,
        "frame": None,
        "embed_nosplit": None,
        "inner_nosplit": None,
        "experts_nosplit": None,
        "layers": None,
    }
    # ---- cache time axis (KV caches dominate memory at 32k+) -------------
    # batch-sharded cells put the cache time dim on "model"; the batch=1
    # long-context cell shards time over BOTH data axes and model
    # (flash-decoding style sequence sharding).
    if _div(gb, n_data):
        rules["kv_time"] = "model" if _div(shape.seq_len, model) else None
    else:
        full = tuple(data_axes) + ("model",)
        n_full = n_data * model
        if _div(shape.seq_len, n_full):
            rules["kv_time"] = full
        elif _div(shape.seq_len, model):
            rules["kv_time"] = "model"
        else:
            rules["kv_time"] = None
    # mlp dim check (all assigned d_ff are divisible by 16, but guard anyway)
    if not _div(cfg.d_ff, model):
        rules["mlp"] = None
        rules["mlp_act"] = None
    return rules


def make_ctx(cfg: ModelConfig, mesh, shape: ShapeSpec) -> ShardingCtx:
    return ShardingCtx(mesh, make_rules(cfg, mesh, shape))


# ---------------------------------------------------------------------------
# Input / state specs for the dry-run (ShapeDtypeStruct, zero allocation)
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, sh: ShardingCtx):
    """ShapeDtypeStructs for a train/prefill batch."""
    import jax.numpy as jnp

    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32,
                               sharding=sh.named_sharding("batch", None))
    if cfg.is_enc_dec:
        frames = jax.ShapeDtypeStruct(
            (B, S, cfg.frame_dim), jnp.float32,
            sharding=sh.named_sharding("batch", None, None))
        return {"frames": frames, "tokens": tok}
    return {"tokens": tok}


def cache_axes_for(name: str, ndim: int, rules: Optional[Dict] = None):
    """Logical axes for a cache leaf, identified by name (+ ndim for the
    zamba mega segment whose leaves carry an extra per-group axis).

    When KV heads shard over the model axis, the cache time axis must not
    also claim "model" (a PartitionSpec may use each mesh axis once) — the
    head axis gives the same memory win, so time drops the overlap.
    """
    rules = rules or {}
    time_ax = "kv_time"
    if rules.get("kv_heads_act") == "model":
        kv_time = rules.get("kv_time")
        axes = kv_time if isinstance(kv_time, tuple) else (kv_time,)
        remaining = tuple(a for a in axes if a not in (None, "model"))
        time_ax = ("kv_time_noverlap" if remaining else None)
        rules.setdefault("kv_time_noverlap", remaining or None)
    if name in ("k", "v"):  # (layers, B, T, Kv, hd)
        return (None, "batch", time_ax, "kv_heads_act", None)
    if name in ("ck", "cv"):  # cross-attention KV (encoder length)
        return (None, "batch", time_ax, "kv_heads_act", None)
    if name in ("latent", "krope"):  # (layers, B, T, r)
        return (None, "batch", "kv_time", None)
    if name == "wkv":  # (layers, B, h, hd, hd)
        return (None, "batch", "ssm_heads_act", None, None)
    if name in ("shift_tm", "shift_cm"):  # (layers, B, d)
        return (None, "batch", None)
    if name == "ssm":  # (layers[, per], B, h, p, n)
        if ndim == 6:
            return (None, None, "batch", "ssm_heads_act", None, None)
        return (None, "batch", "ssm_heads_act", None, None)
    if name == "conv":  # (layers[, per], B, w-1, conv_dim)
        if ndim == 5:
            return (None, None, "batch", None, None)
        return (None, "batch", None, None)
    return (None,) * ndim


def cache_tree_axes(tree, rules=None):
    """Map a cache pytree to logical-axes tuples (by leaf name)."""
    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else None
        return cache_axes_for(name, leaf.ndim, rules)

    return jax.tree_util.tree_map_with_path(one, tree)


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, sh: ShardingCtx,
                enc_len: Optional[int] = None):
    """ShapeDtypeStruct cache tree with shardings for a decode cell."""
    from repro.models.model import init_decode_caches

    shapes = jax.eval_shape(
        lambda: init_decode_caches(cfg, shape.global_batch, shape.seq_len,
                                   enc_len=enc_len))

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else None
        axes = cache_axes_for(name, leaf.ndim, sh.rules)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=sh.named_sharding(*axes))

    return jax.tree_util.tree_map_with_path(spec_for, shapes)


def cache_shardings(cfg: ModelConfig, sh: ShardingCtx, cache_shape_tree):
    """NamedSharding tree for prefill cache OUTPUTS (same name rules)."""
    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else None
        axes = cache_axes_for(name, leaf.ndim, sh.rules)
        return sh.named_sharding(*axes)

    return jax.tree_util.tree_map_with_path(one, cache_shape_tree)


def param_shardings(cfg: ModelConfig, sh: ShardingCtx, axes_tree):
    return sh.param_shardings(axes_tree)


# ---------------------------------------------------------------------------
# Serving-path rules: a geo server as a TP/EP device group
# ---------------------------------------------------------------------------
#
# The pooled serving steps (repro/serving/kv_cache.py) are jitted per
# (cfg, kinds, backend) and lru-cached, so everything that parameterises a
# sharded trace must be hashable: the mesh already is, and ``freeze_rules``
# turns a rules dict into a canonical tuple-of-pairs key.  ``guarded_spec``
# is the single choke point every serving PartitionSpec goes through — it
# drops (replicates) any axis whose mesh extent does not divide the leaf
# dimension, so pool rows, page counts, and round widths chosen by the
# engine can never produce an invalid sharding.


@dataclasses.dataclass(frozen=True)
class DeviceGroup:
    """One server's TP/EP device group: a mesh, its frozen serving rules,
    and (implicitly) the devices the mesh spans.

    ``GeoServingSystem(device_groups={sid: DeviceGroup | None, ...})``
    assigns one group per server — a 2-device TP server and a 4-device EP
    server coexist because every rules/step cache downstream is keyed on
    the group's ``(mesh, rules)`` pair, never on global state.  ``None``
    (either the field or the dict entry) is the byte-identical solo-device
    twin.  ``rules=None`` derives :func:`serving_rules` per server from its
    actual (n_rows, max_len) shapes; a dict or frozen tuple overrides them
    (see :func:`freeze_rules`).  Instances are hashable — they ride in the
    pooled-step ``lru_cache`` keys as ``(mesh, rules)``.
    """

    mesh: object = None
    rules: object = None

    def __post_init__(self):
        if self.rules is not None and not isinstance(self.rules, tuple):
            object.__setattr__(self, "rules", freeze_rules(dict(self.rules)))

    @property
    def devices(self) -> tuple:
        """The group's device list (empty for the solo twin)."""
        if self.mesh is None:
            return ()
        return tuple(self.mesh.devices.reshape(-1))

    @property
    def n_chips(self) -> int:
        """Device count the τ roofline divides by (1 for the solo twin)."""
        return int(self.mesh.devices.size) if self.mesh is not None else 1

    def frozen_rules_for(self, cfg: ModelConfig, n_rows: int, max_len: int):
        """This group's frozen serving rules: the explicit override when
        given, else the cached per-(cfg, mesh, shape) derivation."""
        if self.mesh is None:
            return None
        if self.rules is not None:
            return self.rules
        return frozen_serving_rules(cfg, self.mesh, int(n_rows),
                                    int(max_len))


def as_device_group(group) -> DeviceGroup:
    """Normalize ``None`` | ``Mesh`` | :class:`DeviceGroup` to a
    DeviceGroup — the single entry point the engine funnels both the
    legacy global ``mesh=`` sugar and per-server ``device_groups`` values
    through."""
    if group is None:
        return DeviceGroup()
    if isinstance(group, DeviceGroup):
        return group
    return DeviceGroup(mesh=group)


@functools.lru_cache(maxsize=None)
def frozen_serving_rules(cfg: ModelConfig, mesh, n_rows: int, max_len: int):
    """Frozen :func:`serving_rules`, cached per (cfg, mesh, n_rows,
    max_len) — the per-GROUP rules cache.  Heterogeneous deployments hit
    this once per distinct group geometry: a 2-device TP server and a
    4-device EP server each keep their own entry (the mesh is part of the
    key), so neither rederives nor clobbers the other's rules."""
    return freeze_rules(serving_rules(cfg, mesh, n_rows, max_len))


def serving_rules(cfg: ModelConfig, mesh, n_rows: int,
                  max_len: int) -> Dict[str, object]:
    """Logical-axis rules for the serving hot path: a decode-shaped cell
    whose "batch" is the cache pool's row count.  Sequence-activation
    sharding is forced off — pooled steps vmap one token per row, there is
    no sequence dimension to split."""
    shape = ShapeSpec("serving_decode", max(1, int(max_len)),
                      max(1, int(n_rows)), "decode")
    rules = make_rules(cfg, mesh, shape)
    rules["seq_act"] = None
    rules["attn_seq_q"] = None
    return rules


def freeze_rules(rules: Optional[Dict[str, object]]):
    """Canonical hashable form of a rules dict (for lru_cache keys)."""
    if rules is None:
        return None
    return tuple(sorted(rules.items()))


def thaw_rules(frozen) -> Dict[str, object]:
    return {} if frozen is None else dict(frozen)


def guarded_spec(axes, shape, rules: Dict[str, object], mesh) -> P:
    """PartitionSpec for one leaf: logical axes -> mesh axes with a per-dim
    divisibility guard.  Any dim whose assigned mesh extent does not divide
    it falls back to replication, and a mesh axis is never used twice."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = set()
    spec = []
    for dim, logical in zip(shape, axes):
        mesh_ax = rules.get(logical) if logical else None
        if mesh_ax is None:
            spec.append(None)
            continue
        ax_tuple = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
        ax_tuple = tuple(a for a in ax_tuple
                         if a is not None and a not in used)
        extent = int(np.prod([sizes.get(a, 1) for a in ax_tuple])) \
            if ax_tuple else 1
        if not ax_tuple or not _div(int(dim), extent):
            spec.append(None)
            continue
        used.update(ax_tuple)
        spec.append(ax_tuple if len(ax_tuple) > 1 else ax_tuple[0])
    return P(*spec)


def pool_tree_shardings(mesh, rules: Dict[str, object], pool_trees):
    """NamedSharding tuple-of-trees for a CachePool's pool trees (slab or
    paged layout): per-leaf logical axes via :func:`cache_axes_for`, mapped
    through :func:`guarded_spec`.  Works on arrays or ShapeDtypeStructs."""
    rules = dict(rules)  # cache_axes_for may add the kv_time_noverlap rule

    def one(path, leaf):
        name = next((p.key for p in reversed(path) if hasattr(p, "key")),
                    None)
        axes = cache_axes_for(name, leaf.ndim, rules)
        return NamedSharding(mesh, guarded_spec(axes, leaf.shape, rules,
                                                mesh))

    return jax.tree_util.tree_map_with_path(one, pool_trees)


def block_param_shardings(mesh, rules: Dict[str, object], axes_tree,
                          param_tree):
    """NamedSharding tree for a server's stacked block params: the logical
    axes tree from ``models.model.block_param_axes`` mapped through
    :func:`guarded_spec` against the actual leaf shapes."""
    return jax.tree.map(
        lambda ax, p: NamedSharding(
            mesh, guarded_spec(ax, p.shape, rules, mesh)),
        axes_tree, param_tree,
        is_leaf=lambda x: isinstance(x, tuple))
