"""Render the dry-run artifacts into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import glob
import json
import os
import sys


def load(dirpath="experiments/dryrun"):
    rows = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def fmt_ms(x):
    return f"{x*1e3:.1f}"


def markdown_table(rows, mesh="16x16"):
    out = ["| arch | shape | compute ms | memory ms (tpu-est) | collective ms"
           " | dominant | useful FLOPs | peak HBM GB (tpu-est) | fits |",
           "|---|---|---|---|---|---|---|---|---|"]
    shape_order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
                   "long_500k": 3}
    sel = [r for r in rows if r["mesh"] == mesh]
    sel.sort(key=lambda r: (r["arch"], shape_order.get(r["shape"], 9)))
    for r in sel:
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(t['compute_s'])} | "
            f"{fmt_ms(t['memory_s'])} ({fmt_ms(t['memory_s_tpu_est'])}) | "
            f"{fmt_ms(t['collective_s'])} | {t['dominant']} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['memory']['peak_hbm_bytes']/1e9:.1f} "
            f"({r['peak_hbm_tpu_est_bytes']/1e9:.1f}) | "
            f"{'Y' if r['fits_hbm_16g_tpu_est'] else 'N'} |")
    return "\n".join(out)


def summary(rows):
    worst = sorted(
        (r for r in rows if r["mesh"] == "16x16"
         and r["roofline"]["bound_s"] > 0),
        key=lambda r: r["roofline"]["compute_s"] / r["roofline"]["bound_s"])
    coll = sorted(
        (r for r in rows if r["mesh"] == "16x16"),
        key=lambda r: -r["roofline"]["collective_s"])
    lines = ["worst roofline fraction (single-pod):"]
    for r in worst[:5]:
        t = r["roofline"]
        lines.append(f"  {r['arch']}/{r['shape']}: "
                     f"compute/bound={t['compute_s']/t['bound_s']:.3f} "
                     f"dominant={t['dominant']}")
    lines.append("most collective-bound:")
    for r in coll[:5]:
        lines.append(f"  {r['arch']}/{r['shape']}: "
                     f"coll={r['roofline']['collective_s']*1e3:.0f}ms "
                     f"compute={r['roofline']['compute_s']*1e3:.0f}ms")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = load(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    print(f"{len(rows)} artifacts\n")
    print("## single-pod 16x16\n")
    print(markdown_table(rows, "16x16"))
    print("\n## multi-pod 2x16x16\n")
    print(markdown_table(rows, "2x16x16"))
    print()
    print(summary(rows))
