"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set the fake-device flag before ANY other import (jax locks the device
count at first init).  Merged into — not overwriting — any XLA_FLAGS the
user already exported (repro.launch.xla_flags is stdlib-only)."""
import os

from repro.launch.xla_flags import force_host_device_count

force_host_device_count(os.environ, 512)

import argparse  # noqa: E402
import gc  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import (  # noqa: E402
    ARCH_IDS, SHAPES_BY_NAME, ModelConfig, ShapeSpec, get_config)
from repro.launch import costs as C  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.sharding import (  # noqa: E402
    batch_specs, cache_shardings, cache_specs, make_ctx)
from repro.models.layers import ShardingCtx  # noqa: E402
from repro.models.model import (  # noqa: E402
    decode_step, init_params_shapes, make_decode_body, make_full_body,
    prefill, stack_plan)
from repro.training.train_step import (  # noqa: E402
    TrainHParams, make_optimizer_for, make_train_step)


# ---------------------------------------------------------------------------
# Spec plumbing
# ---------------------------------------------------------------------------


def _with_shardings(shape_tree, sharding_tree):
    return jax.tree.map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        shape_tree, sharding_tree)


def param_specs(cfg: ModelConfig, sh: ShardingCtx):
    shapes, axes = init_params_shapes(cfg)
    shardings = sh.param_shardings(axes)
    return _with_shardings(shapes, shardings), axes, shardings


def _slice_leading(tree):
    """SDS tree with the leading (scan) axis removed."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), tree)


def _slice_axes(axes_tree):
    return jax.tree.map(lambda a: a[1:], axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def input_specs(arch: str, shape_name: str, mesh) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    sh = make_ctx(cfg, mesh, shape)
    out: Dict = {"cfg": cfg, "shape": shape, "sh": sh}
    pspecs, axes, pshard = param_specs(cfg, sh)
    out["params"] = pspecs
    out["param_axes"] = axes
    out["param_shardings"] = pshard
    if shape.kind in ("train", "prefill"):
        out["batch"] = batch_specs(cfg, shape, sh)
    if shape.kind == "decode":
        out["caches"] = cache_specs(cfg, shape, sh, enc_len=shape.seq_len)
        out["tokens"] = jax.ShapeDtypeStruct(
            (shape.global_batch,), jnp.int32,
            sharding=sh.named_sharding("batch"))
    return out


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               with_corrections: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    sh = make_ctx(cfg, mesh, shape)
    spec = input_specs(arch, shape_name, mesh)
    t0 = time.time()

    if shape.kind == "train":
        hp = TrainHParams(remat=True, grad_accum=1)
        opt = make_optimizer_for(cfg, hp)
        step_fn = make_train_step(cfg, sh, opt, hp)
        opt_shapes = jax.eval_shape(opt.init, spec["params"])
        opt_shardings = _opt_shardings(opt, spec["param_shardings"],
                                       opt_shapes, mesh)
        state_sds = {
            "params": spec["params"],
            "opt": _with_shardings(opt_shapes, opt_shardings),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        lowered = jax.jit(step_fn, donate_argnums=0).lower(
            state_sds, spec["batch"])
    elif shape.kind == "prefill":
        def fn(params, batch):
            return prefill(params, cfg, sh, batch, cache_len=shape.seq_len)

        out_shapes = jax.eval_shape(fn, spec["params"], spec["batch"])
        logits_ns = sh.named_sharding("batch", "vocab")
        cache_ns = cache_shardings(cfg, sh, out_shapes[1])
        lowered = jax.jit(fn, out_shardings=(logits_ns, cache_ns)).lower(
            spec["params"], spec["batch"])
    else:  # decode
        pos = shape.seq_len - 1

        def fn(params, caches, tokens):
            return decode_step(params, cfg, sh, caches, tokens, pos)

        cache_ns = jax.tree.map(lambda s: s.sharding, spec["caches"])
        logits_ns = sh.named_sharding("batch", "vocab")
        lowered = jax.jit(fn, donate_argnums=1,
                          out_shardings=(logits_ns, cache_ns)).lower(
            spec["params"], spec["caches"], spec["tokens"])

    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = C.memory_summary(compiled)
    raw = C.summarize_compiled(compiled)

    corrected = C.CostSummary()
    corrected.scaled_add(raw, 1.0)
    seg_costs = {}
    if with_corrections:
        for seg in stack_plan(cfg):
            k = seg.n - 1
            if k <= 0:
                continue
            if shape.kind == "decode" and seg.kind == "enc":
                continue  # encoder does no decode-time work
            fwd, bwd = _segment_body_costs(cfg, sh, shape, spec, seg,
                                           train=(shape.kind == "train"))
            corrected.scaled_add(fwd, float(k))
            seg_costs[seg.name] = {"n": seg.n, "fwd": fwd.to_dict()}
            if bwd is not None:
                corrected.scaled_add(bwd, float(k))
                seg_costs[seg.name]["bwd"] = bwd.to_dict()

    # analytic HBM-traffic floor: everything the step necessarily touches
    # once per device (params + opt state + caches = args; outputs), plus the
    # remat stash (written fwd, read bwd) for training.
    stash = 0.0
    if shape.kind == "train":
        n_data = n_chips // 16  # data axes product (model axis is 16)
        stash = (shape.global_batch / n_data) * shape.seq_len \
            * cfg.d_model * 2 * cfg.n_layers
        seq_rule = sh.rules.get("seq_act")
        if seq_rule is not None:
            stash /= 16
    mem_floor = (mem["argument_size_in_bytes"]
                 + mem["output_size_in_bytes"] + 2.0 * stash)
    terms = C.roofline_terms(corrected, n_chips, mem_floor_bytes=mem_floor)
    model_flops = _model_flops_per_device(cfg, shape, n_chips)
    # TPU-peak estimate: true-dtype args + half of the f32-inflated temps
    peak_tpu_est = (mem["argument_size_in_bytes"]
                    + mem["output_size_in_bytes"]
                    + mem["temp_size_in_bytes"] / 2.0
                    - mem["alias_size_in_bytes"])
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "compile_seconds": round(compile_s, 2),
        "memory": mem,
        "raw_cost": raw.to_dict(),
        "corrected_cost": corrected.to_dict(),
        "segments": seg_costs,
        "roofline": terms,
        "model_flops_per_device": model_flops,
        "useful_flops_ratio": (model_flops / corrected.flops
                               if corrected.flops else 0.0),
        "fits_hbm_16g_raw": bool(mem["peak_hbm_bytes"] < 16e9),
        "peak_hbm_tpu_est_bytes": peak_tpu_est,
        "fits_hbm_16g_tpu_est": bool(peak_tpu_est < 16e9),
    }
    del compiled, lowered
    gc.collect()
    return result


def _opt_shardings(opt, param_shardings, opt_shapes, mesh):
    """Optimizer-state shardings: adamw m/v mirror params; scalars/factored
    stats fall back to replication (they are comparatively small)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    rep = NamedSharding(mesh, P())
    if opt.name == "adamw":
        return {"m": param_shardings, "v": param_shardings}
    return jax.tree.map(lambda s: rep, opt_shapes)


def _model_flops_per_device(cfg: ModelConfig, shape: ShapeSpec,
                            n_chips: int) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:
        total = 2.0 * n_active * shape.global_batch
    return total / n_chips


# ---------------------------------------------------------------------------
# Segment-body lowering for the exact scan-cost correction
# ---------------------------------------------------------------------------


def _segment_body_costs(cfg, sh: ShardingCtx, shape: ShapeSpec, spec, seg,
                        train: bool):
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.param_dtype)
    h_sds = jax.ShapeDtypeStruct(
        (B, 1 if shape.kind == "decode" else S, cfg.d_model), dt,
        sharding=sh.named_sharding("batch", "seq_act" if train else None,
                                   None))
    p_slice = _with_shardings(
        _slice_leading(spec["params"]["segments"][seg.name]),
        sh.param_shardings(
            _slice_axes(spec["param_axes"]["segments"][seg.name])))
    idx_sds = jax.ShapeDtypeStruct((), jnp.int32)
    aux_sds = {"moe_aux_loss": jax.ShapeDtypeStruct((), jnp.float32),
               "moe_drop_frac": jax.ShapeDtypeStruct((), jnp.float32)}
    emb_sds = h_sds  # emb0 for zamba mega
    shared_sds = spec["params"].get("shared")

    if shape.kind == "decode":
        pos = shape.seq_len - 1
        # keep per-leaf cache shardings on the sliced (per-layer) specs —
        # lowering the body with unsharded caches would overcount per-device
        # bytes by the full sharding factor
        from repro.launch.sharding import cache_axes_for

        def _slice_cache_spec(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else None
            axes = cache_axes_for(name, leaf.ndim, sh.rules)[1:]
            return jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype,
                                        sharding=sh.named_sharding(*axes))

        cache_slice = jax.tree_util.tree_map_with_path(
            _slice_cache_spec, spec["caches"][seg.name])

        def fwd_fn(p, c, h, emb0, shared):
            body = make_decode_body(seg, cfg, sh, pos, emb0=emb0,
                                    shared_params=shared)
            xs = (p, c, jnp.int32(0)) if seg.kind == "decoder" else (p, c)
            return body(h, xs)

        args = (p_slice, cache_slice, h_sds, emb_sds, shared_sds)
        fwd = _lower_cost(fwd_fn, args)
        return fwd, None

    positions_sds = jax.ShapeDtypeStruct((S,), jnp.int32)
    collect = shape.kind == "prefill"

    def fwd_fn(p, h, aux, positions, emb0, enc_h, shared):
        body = make_full_body(seg, cfg, sh, positions, emb0=emb0,
                              enc_h=enc_h, collect_caches=collect,
                              shared_params=shared)
        if seg.kind == "decoder":
            return body((h, aux), (p, jnp.int32(0)))
        return body(h, (p, None))

    args = (p_slice, h_sds, aux_sds, positions_sds, emb_sds, h_sds,
            shared_sds)
    fwd = _lower_cost(fwd_fn, args)
    bwd = None
    if train:
        body = None

        def loss_like(p, h, aux, positions, emb0, enc_h, shared):
            out = fwd_fn(p, h, aux, positions, emb0, enc_h, shared)
            carry = out[0] if seg.kind == "decoder" else out[0]
            return carry

        remat_fn = jax.checkpoint(
            loss_like, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=())

        def bwd_fn(p, h, aux, positions, emb0, enc_h, shared, ct_h, ct_aux):
            outs, vjp = jax.vjp(
                lambda pp, hh, aa: remat_fn(pp, hh, aa, positions, emb0,
                                            enc_h, shared), p, h, aux)
            ct = (ct_h, ct_aux) if seg.kind == "decoder" else ct_h
            return vjp(ct)

        ct_h = h_sds
        bwd = _lower_cost(bwd_fn, args + (ct_h, aux_sds))
    return fwd, bwd


def _lower_cost(fn, arg_specs) -> C.CostSummary:
    lowered = jax.jit(fn).lower(*arg_specs)
    compiled = lowered.compile()
    out = C.summarize_compiled(compiled)
    del compiled, lowered
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def runnable_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in cfg.shapes():
            yield arch, shape.name
        for shape_name, reason in cfg.skip_reasons().items():
            yield arch, f"SKIP:{shape_name}:{reason}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-corrections", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for arch, shape in runnable_cells():
            print(f"{arch},{shape}")
        return

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    meshes = args.mesh.split(",")
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([s.name for s in cfg.shapes()] if args.shape == "all"
                  else [s for s in args.shape.split(",")
                        if s in {x.name for x in cfg.shapes()}])
        for shape_name in shapes:
            for mesh_kind in meshes:
                multi = mesh_kind == "multi"
                tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"skip (exists) {tag}")
                    continue
                print(f"=== {tag} ===", flush=True)
                try:
                    res = lower_cell(arch, shape_name, multi,
                                     with_corrections=not args.no_corrections)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    r = res["roofline"]
                    print(f"  ok compile={res['compile_seconds']}s "
                          f"peak_hbm={res['memory']['peak_hbm_bytes']/1e9:.2f}GB "
                          f"compute={r['compute_s']*1e3:.2f}ms "
                          f"memory={r['memory_s']*1e3:.2f}ms "
                          f"coll={r['collective_s']*1e3:.2f}ms "
                          f"dominant={r['dominant']} "
                          f"useful={res['useful_flops_ratio']:.3f}",
                          flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"  FAIL {e}", flush=True)
                    traceback.print_exc()
                gc.collect()
    if failures:
        print("\nFAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err}")
        raise SystemExit(1)
    print("\nall requested cells lowered + compiled OK")


if __name__ == "__main__":
    main()
