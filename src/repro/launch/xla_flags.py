"""XLA_FLAGS composition (stdlib-only: importable before jax).

The dry-run and the forced-multi-device test lanes need
``--xla_force_host_platform_device_count=N`` set BEFORE jax initialises —
but overwriting ``os.environ["XLA_FLAGS"]`` wholesale silently drops
whatever flags the user (or a CI lane) already exported.  ``merge_xla_flags``
appends instead: existing flags are preserved verbatim, and a flag that is
already present (by name) wins over the requested one — an explicit user
setting is never clobbered.
"""
from __future__ import annotations

from typing import Optional


def _flag_name(flag: str) -> str:
    return flag.split("=", 1)[0]


def merge_xla_flags(existing: Optional[str], *new_flags: str) -> str:
    """Merge ``new_flags`` into an existing ``XLA_FLAGS`` string.

    * existing flags keep their order and values,
    * a new flag whose name already appears is DROPPED (user wins),
    * remaining new flags are appended in the given order.
    """
    current = (existing or "").split()
    present = {_flag_name(f) for f in current}
    merged = current + [f for f in new_flags
                        if _flag_name(f) not in present]
    return " ".join(merged)


def force_host_device_count(environ, n: int) -> str:
    """Set ``--xla_force_host_platform_device_count=n`` in ``environ``
    (a mutable mapping, normally ``os.environ``) without clobbering any
    flags already there.  Returns the merged string.  If the user already
    forced a device count, theirs is kept."""
    merged = merge_xla_flags(
        environ.get("XLA_FLAGS"),
        f"--xla_force_host_platform_device_count={int(n)}")
    environ["XLA_FLAGS"] = merged
    return merged
