"""Training launcher: real-device entry point for any assigned arch.

On a TPU fleet this runs under the usual multi-host bootstrap
(jax.distributed.initialize); on this CPU container use --reduced for a
smoke-scale run.  Includes the XLA latency-hiding-scheduler flags used for
compute/collective overlap on real hardware (DESIGN.md §7).

    PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b \
        --reduced --steps 30
"""
import os

_TPU_PERF_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_megacore_fusion_allow_ags=true "
    "--xla_enable_async_collective_permute=true "
)
if os.environ.get("REPRO_TPU_FLAGS", "0") == "1":
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " " + _TPU_PERF_FLAGS)

import argparse  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES_BY_NAME, get_config, get_reduced_config  # noqa: E402
from repro.data import make_batches  # noqa: E402
from repro.launch.mesh import make_mesh_for  # noqa: E402
from repro.launch.sharding import make_ctx  # noqa: E402
from repro.models.layers import NULL_SH  # noqa: E402
from repro.training import (TrainHParams, checkpoint, init_train_state,  # noqa: E402
                            make_optimizer_for, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    hp = TrainHParams(learning_rate=args.lr, grad_accum=args.grad_accum)
    opt = make_optimizer_for(cfg, hp)
    if args.model_parallel > 1:
        mesh = make_mesh_for(model_parallel=args.model_parallel)
        shape = SHAPES_BY_NAME["train_4k"]
        sh = make_ctx(cfg, mesh, shape)
    else:
        sh = NULL_SH
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step_fn = jax.jit(make_train_step(cfg, sh, opt, hp))
    start = 0
    if args.ckpt and checkpoint.latest_step(args.ckpt):
        state, start = checkpoint.restore(args.ckpt, state)
        print(f"resumed at step {start}")
    batches = make_batches(cfg, args.batch, args.seq, seed=0,
                           start_step=start)
    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        state, metrics = step_fn(state, batch)
        if (i + 1) % 5 == 0:
            print(f"step {i+1} loss {float(metrics['loss']):.4f} "
                  f"({(time.time()-t0)/5:.2f}s/step)")
            t0 = time.time()
        if args.ckpt and (i + 1) % 20 == 0:
            checkpoint.save(args.ckpt, i + 1, state)
    print("done")


if __name__ == "__main__":
    main()
