"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds the mesh.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_for(n_devices: Optional[int] = None, model_parallel: int = 1):
    """Small utility mesh for tests/examples (1..N local devices)."""
    n = n_devices or len(jax.devices())
    data = n // model_parallel
    return jax.make_mesh(
        (data, model_parallel), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes that carry the batch (data-parallel) dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
