"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds the mesh.

``compat_make_mesh`` papers over the ``jax.sharding.AxisType`` API churn:
newer jax versions want explicit axis types (and deprecate the implicit
default), older versions (<= 0.4.x) don't expose ``AxisType`` at all and
``jax.make_mesh`` rejects the ``axis_types`` kwarg.  All mesh construction
in this repo (and the tests) goes through this helper.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np


def compat_make_mesh(shape, axis_names, devices=None):
    """Version-compatible ``jax.make_mesh`` with Auto axis types when the
    running jax supports them.

    ``devices``: optional explicit device list (``prod(shape)`` of them) —
    the heterogeneous-group path: ``jax.make_mesh`` insists on covering
    ALL local devices, but a per-server :class:`DeviceGroup` mesh spans a
    SUBSET, so those are built directly over the given slice (in the given
    order, keeping device partitions disjoint and deterministic)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if devices is not None:
        arr = np.asarray(list(devices), dtype=object).reshape(tuple(shape))
        if axis_type is not None:
            try:
                return jax.sharding.Mesh(
                    arr, tuple(axis_names),
                    axis_types=(axis_type.Auto,) * len(axis_names))
            except TypeError:
                pass
        return jax.sharding.Mesh(arr, tuple(axis_names))
    if axis_type is not None:
        try:
            return jax.make_mesh(
                tuple(shape), tuple(axis_names),
                axis_types=(axis_type.Auto,) * len(axis_names))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(tuple(shape), tuple(axis_names))


def group_meshes(group_shapes: Dict, axis_names=("data", "model"),
                 devices: Optional[Sequence] = None) -> Dict:
    """Carve the host's devices into disjoint per-server meshes.

    ``group_shapes`` maps server id -> mesh shape tuple or None (solo
    twin).  Servers are assigned consecutive device slices in sorted-key
    order; ``None`` takes no devices (the solo twin computes on the
    default device).  Returns {server_id: Mesh | None} — feed it through
    ``DeviceGroup``/``GeoServingSystem(device_groups=...)``.  Raises when
    the shapes ask for more devices than the host exposes."""
    devs = list(devices if devices is not None else jax.devices())
    out, off = {}, 0
    for j in sorted(group_shapes):
        shape = group_shapes[j]
        if shape is None:
            out[j] = None
            continue
        n = int(np.prod(shape))
        if off + n > len(devs):
            raise ValueError(
                f"device groups need {off + n} devices, host has "
                f"{len(devs)} (shapes {group_shapes})")
        out[j] = compat_make_mesh(shape, axis_names, devs[off:off + n])
        off += n
    return out


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_mesh_for(n_devices: Optional[int] = None, model_parallel: int = 1):
    """Small utility mesh for tests/examples (1..N local devices)."""
    n = n_devices or len(jax.devices())
    data = n // model_parallel
    return compat_make_mesh((data, model_parallel), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes that carry the batch (data-parallel) dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
