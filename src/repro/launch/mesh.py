"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds the mesh.

``compat_make_mesh`` papers over the ``jax.sharding.AxisType`` API churn:
newer jax versions want explicit axis types (and deprecate the implicit
default), older versions (<= 0.4.x) don't expose ``AxisType`` at all and
``jax.make_mesh`` rejects the ``axis_types`` kwarg.  All mesh construction
in this repo (and the tests) goes through this helper.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def compat_make_mesh(shape, axis_names):
    """Version-compatible ``jax.make_mesh`` with Auto axis types when the
    running jax supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                tuple(shape), tuple(axis_names),
                axis_types=(axis_type.Auto,) * len(axis_names))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(tuple(shape), tuple(axis_names))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_mesh_for(n_devices: Optional[int] = None, model_parallel: int = 1):
    """Small utility mesh for tests/examples (1..N local devices)."""
    n = n_devices or len(jax.devices())
    data = n // model_parallel
    return compat_make_mesh((data, model_parallel), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes that carry the batch (data-parallel) dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
