"""Serving launcher: geo-distributed BPRR serving of a (reduced) arch.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b \
        --requests 5 --algorithm proposed
"""
import argparse

import numpy as np
import jax

from repro.configs import get_reduced_config
from repro.core import GB, LLMSpec, Problem, ServerSpec, Workload
from repro.models import init_params
from repro.serving import GeoServingSystem, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--algorithm", default="proposed",
                    choices=["proposed", "petals"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--servers", type=int, default=4)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    llm = LLMSpec(cfg.name, cfg.n_layers, block_bytes=50.0,
                  cache_bytes_per_token=0.5)
    rng = np.random.RandomState(0)
    servers = [ServerSpec(j, mem_bytes=50.0 * cfg.n_layers * 2,
                          tau=0.005 * (1 + j % 3))
               for j in range(args.servers)]
    rtt = 0.01 + 0.02 * rng.rand(1, args.servers)
    problem = Problem(llm, servers, 1, rtt, 3 * rtt,
                      workload=Workload(8, args.new_tokens))
    system = GeoServingSystem(cfg, params, problem,
                              algorithm=args.algorithm,
                              max_new_tokens=args.new_tokens + 4)
    print(f"{args.algorithm} placement: a={system.placement.a} "
          f"m={system.placement.m}")
    for r in range(args.requests):
        toks = rng.randint(2, cfg.vocab_size, 8)
        out, vt = generate(system, toks, args.new_tokens)
        print(f"req {r}: virtual {vt:.3f}s  tokens {out[8:8+6]}...")


if __name__ == "__main__":
    main()
