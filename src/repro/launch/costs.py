"""Cost extraction from compiled XLA artifacts (dry-run roofline inputs).

Two jobs:

1. ``parse_collectives`` — sum per-device *wire bytes* of every collective in
   a post-optimization HLO module, using standard ring-algorithm factors:
       all-reduce       2(g-1)/g * N      (N = per-device operand bytes)
       all-gather       (g-1)/g * N_out
       reduce-scatter   (g-1) * N_out
       all-to-all       (g-1)/g * N
       collective-permute  N
   (g = replica-group size; groups of size 1 contribute nothing.)

2. ``CostSummary`` accounting with the scan correction: XLA cost_analysis
   counts a ``while`` body once, so the dry-run lowers every scan-segment
   body separately and reports  total = full + Σ_i body_i × (n_i − 1)
   (exact for scanned stacks — verified in DESIGN.md §6).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> Dict:
    """Returns {"wire_bytes", "raw_bytes", "count", "by_kind": {...}}."""
    wire = 0.0
    raw = 0
    by_kind: Dict[str, float] = {}
    count = 0
    for m in _COLL_RE.finditer(hlo_text):
        tuple_body, dtype, dims, kind = m.groups()
        if tuple_body is not None:
            # tuple-shaped output (e.g. fused start ops): sum elements
            out_bytes = sum(_shape_bytes(d, s)
                            for d, s in _SHAPE_RE.findall(tuple_body))
        else:
            out_bytes = _shape_bytes(dtype, dims)
        # group size
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start(): line_end if line_end > 0 else None]
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            if gb:
                g = len(gb.group(1).split(","))
        if kind == "collective-permute":
            # permutes carry source_target_pairs, not replica_groups
            count += 1
            raw += out_bytes
            wire += float(out_bytes)
            by_kind[kind] = by_kind.get(kind, 0.0) + float(out_bytes)
            continue
        if g <= 1:
            continue
        count += 1
        raw += out_bytes
        if kind == "all-reduce":
            w = 2.0 * (g - 1) / g * out_bytes
        elif kind == "all-gather":
            w = (g - 1) / g * out_bytes
        elif kind == "reduce-scatter":
            w = float(g - 1) * out_bytes
        elif kind == "all-to-all":
            w = (g - 1) / g * out_bytes
        else:  # collective-permute
            w = float(out_bytes)
        wire += w
        by_kind[kind] = by_kind.get(kind, 0.0) + w
    return {"wire_bytes": wire, "raw_bytes": raw, "count": count,
            "by_kind": by_kind}


@dataclass
class CostSummary:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_count: int = 0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)

    def scaled_add(self, other: "CostSummary", k: float):
        self.flops += k * other.flops
        self.bytes_accessed += k * other.bytes_accessed
        self.coll_wire_bytes += k * other.coll_wire_bytes
        self.coll_count += int(k * other.coll_count)
        for kk, v in other.coll_by_kind.items():
            self.coll_by_kind[kk] = self.coll_by_kind.get(kk, 0.0) + k * v

    def to_dict(self):
        return {"flops": self.flops, "bytes_accessed": self.bytes_accessed,
                "coll_wire_bytes": self.coll_wire_bytes,
                "coll_count": self.coll_count,
                "coll_by_kind": dict(self.coll_by_kind)}


def summarize_compiled(compiled) -> CostSummary:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax <= 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    ca = ca or {}
    colls = parse_collectives(compiled.as_text())
    return CostSummary(
        flops=float(ca.get("flops", 0.0) or 0.0),
        bytes_accessed=float(ca.get("bytes accessed", 0.0) or 0.0),
        coll_wire_bytes=colls["wire_bytes"],
        coll_count=colls["count"],
        coll_by_kind=colls["by_kind"],
    )


def memory_summary(compiled) -> Dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        out[k] = int(getattr(ma, k, 0) or 0)
    out["peak_hbm_bytes"] = (out["argument_size_in_bytes"]
                             + out["output_size_in_bytes"]
                             + out["temp_size_in_bytes"]
                             - out["alias_size_in_bytes"])
    return out


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12  # TPU v5e-class, per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (assignment constant)


def tau_from_step_cost(cost: CostSummary, n_chips: int, m_blocks: int,
                       n_rows: int) -> float:
    """Per-block per-token decode τ (s) from one pooled decode step's cost.

    The step advances every pool row one token through all ``m_blocks``
    hosted blocks, so the roofline bound of ONE dispatch amortises over
    ``m_blocks x n_rows`` (block, token) pairs — exactly the τ the paper's
    eq. (1) multiplies back up.  With a sharded step the cost analysis is
    per-device after SPMD partitioning, so a TP/EP device group's speedup
    (and its collective bytes) land in τ automatically."""
    terms = roofline_terms(cost, n_chips)
    return terms["bound_s"] / max(1, int(m_blocks) * int(n_rows))


def roofline_terms(cost: CostSummary, n_chips: int,
                   mem_floor_bytes: float = 0.0) -> Dict:
    """cost_analysis numbers are PER-DEVICE after SPMD partitioning, so the
    per-chip terms divide by the per-chip rates directly.

    CPU-backend caveat (DESIGN.md §6): XLA:CPU has no native bf16 GEMMs, so it
    upcasts bf16 dots/gathers to f32 — ``bytes_accessed`` (and temp memory)
    overstate a real bf16 TPU program by up to ~2x.  We therefore report
    three memory numbers: the spec-mandated HLO figure, a /2 "tpu_est"
    adjustment for bf16 programs, and an analytic floor (params+caches+
    outputs actually touched, from per-device argument/output sizes).
    """
    compute_s = cost.flops / PEAK_FLOPS_BF16
    memory_s = cost.bytes_accessed / HBM_BW
    memory_s_tpu_est = max(cost.bytes_accessed / 2.0, mem_floor_bytes) / HBM_BW
    memory_s_floor = mem_floor_bytes / HBM_BW
    collective_s = cost.coll_wire_bytes / ICI_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda t: t[1])[0]
    total = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_s_tpu_est": memory_s_tpu_est,
        "memory_s_floor": memory_s_floor,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": total,
        "compute_fraction_of_bound": (compute_s / total) if total > 0 else 0.0,
    }
